"""The BENCH_solver.json perf trajectory (ISSUE 6).

One seeded, schema-stable JSON document summarizing the solve
pipeline's performance per backend-spec family, emitted by
``benchmarks/run.py --json`` at the repo root so future PRs can diff
trajectories (``tools/check_bench.py`` validates the schema and the
determinism split).

Per spec the document separates three subtrees:

- ``modeled`` — calibrated-model quantities (``nvm/store.py``
  constants): persist cost per event/iteration, the sync pipeline's
  exposed cost, drain cost, storage overhead vs a single PRD node.
  Deterministic for a fixed seed.
- ``counts`` — integer accounting of the traced campaign run
  (iterations, persist commits/aborts, recoveries, restarts, storage
  kills, wasted iterations), cross-checked against the tracer with
  :func:`repro.obs.check_trace_report`.  Deterministic for a fixed
  seed.
- ``wall`` — anything touching measured wall-clock: the overlap
  pipeline's hidden fraction and residual exposure (hidden cost is
  ``min(modeled commit, measured compute window)``), iterations/s of
  the simulation, and the recovery latency measured from the tracer's
  ``recovery.fetch``/``recovery.reconstruct`` spans.  NOT compared by
  the determinism check.

The document also carries a top-level ``sharded`` subtree (ISSUE 7,
DESIGN.md §10): per device-shard count, the per-shard bytes a
shard-kill campaign moves (``bytes`` — deterministic) and the overlap
pipeline's hidden fraction at that shard count (``wall``).  Shard
counts the running process cannot build a mesh for are skipped; the
1-shard row is always present (``benchmarks/run.py --json`` fakes 8
host devices so the committed document carries 1/4/8).

The ``service`` subtree (ISSUE 9, DESIGN.md §12) replays the shared
seeded request trace (``repro.serving.trace``) through the multi-tenant
:class:`~repro.serving.SolveService` twice — without failures and with
every tenant carrying a survivable failure campaign — and reports the
admission/queue statistics in deterministic service *steps*
(``counts``: completions, queue-wait p50/p99, mean batch occupancy,
total service steps) plus the measured throughput (``wall``:
solves/sec), the latter excluded from the determinism contract like
every other wall subtree.

Schema: docs/observability.md §4; ``tools/check_bench.py`` is the gate.
"""
from __future__ import annotations

import os
import time

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.launch.report import storage_values
from repro.obs import Tracer, check_trace_report
from repro.solvers import (
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
)

SCHEMA_VERSION = "repro-bench/v1"

#: one canonical composition per registered backend family, the same
#: coverage rule the campaign-fuzz harness enforces on its SPECS tuple
SPECS = (
    "esr",
    "nvm-homogeneous",
    "nvm-prd",
    "tiered(nvm-homogeneous)",
    "replicated(nvm-prd x2)",
    "erasure(nvm-prd x4+p)",
    "erasure(nvm-prd x6+2p)",
)


#: device-shard counts the sharded row sweeps (nblocks=8 divides all)
SHARD_COUNTS = (1, 4, 8)


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def _family(spec: str) -> str:
    return spec.split("(")[0]


def build(seed: int = 0, smoke: bool = None) -> dict:
    """Build the trajectory document (pure data, JSON-ready).

    The ``seed`` picks the campaign's trigger iteration; everything
    outside the ``wall`` subtrees (and the ``generated`` stamp) is a
    pure function of ``(seed, smoke)`` — the determinism contract
    ``tools/check_bench.py`` verifies with two back-to-back runs.
    """
    if smoke is None:
        smoke = _smoke()
    if smoke:
        grid, nblocks, tol = (8, 8, 8), 4, 1e-8
    else:
        grid, nblocks, tol = (16, 16, 16), 8, 1e-10
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)

    # seeded campaign: one block failure, trigger derived from the seed
    # (kept past the first durable persistence run)
    at = 4 + (seed % 5)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=at),))

    baseline = storage_values(
        make_backend("nvm-prd", op, solver=make_solver("pcg", op, pre)))

    specs = {}
    for spec in SPECS:
        # -- sync run: the fully modeled pipeline (no wall-clock input)
        solver = make_solver("pcg", op, pre)
        be = make_backend(spec, op, solver=solver)
        _, sync_rep, _ = solve(solver, op, b, pre,
                               SolveConfig(tol=tol, maxiter=20000,
                                           persist_mode="sync"),
                               backend=be)
        iters = max(sync_rep.iterations, 1)
        events = max(sync_rep.persist_events, 1)

        # -- overlap run under the campaign, traced end to end
        solver = make_solver("pcg", op, pre)
        be = make_backend(spec, op, solver=solver)
        tracer = Tracer()
        t0 = time.perf_counter()
        _, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=tol, maxiter=20000,
                                      persist_mode="overlap", tracer=tracer),
                          backend=be, failures=campaign)
        wall_s = time.perf_counter() - t0
        check_trace_report(tracer, rep)  # the fuzz harness's invariant
        recovery_s = sum(
            r["dur"] for r in tracer.records
            if r["type"] == "span"
            and r["name"] in ("recovery.fetch", "recovery.reconstruct"))

        specs[spec] = {
            "family": _family(spec),
            "modeled": {
                "persist_s_per_event": sync_rep.persist_cost_s / events,
                "persist_s_per_iter": sync_rep.persist_cost_s / iters,
                # sync = the host-pull baseline: everything exposed
                "exposed_persist_s_per_iter":
                    sync_rep.persist_exposed_s / iters,
                "drain_s": sync_rep.persist_drain_s,
                "storage_overhead_x": storage_values(be) / baseline,
            },
            "counts": {
                "iterations": rep.iterations,
                "converged": bool(rep.converged),
                "persist_events": rep.persist_events,
                "persist_aborts": rep.persist_aborts,
                "failures_recovered": rep.failures_recovered,
                "recovery_restarts": rep.recovery_restarts,
                "storage_failures": rep.storage_failures,
                "wasted_iterations": rep.wasted_iterations,
            },
            "wall": {
                "hidden_fraction": rep.persist_hidden_fraction,
                "exposed_persist_s_per_iter":
                    rep.persist_exposed_s / max(rep.iterations, 1),
                "iterations_per_s": rep.iterations / max(wall_s, 1e-12),
                "recovery_latency_s": recovery_s,
            },
        }

    return {
        "schema": SCHEMA_VERSION,
        "bench": "solver",
        "seed": int(seed),
        "smoke": bool(smoke),
        "solver": "pcg",
        "problem": {"grid": list(grid), "nblocks": nblocks, "n": op.n,
                    "tol": tol,
                    "campaign": {"blocks": [1], "at_iteration": at}},
        "specs": specs,
        "sharded": _sharded_rows(grid, tol, at),
        "service": _service_rows(seed, smoke),
        "persist_kernels": _persist_kernel_rows(grid, nblocks, tol, at),
    }


def _persist_kernel_rows(grid, nblocks: int, tol: float, at: int) -> dict:
    """The fused persist-kernel rows (ISSUE 10, DESIGN.md §13): the
    x6+2p overlap campaign solve run back to back through the numpy
    ("ref") and fused Pallas persist routes.  Deterministic subtrees:
    the stripe encode geometry (bytes the encode moves per event, plus
    the fused update+staging pass's HBM traffic model) and the
    bit-identity/accounting cross-checks.  The hidden fractions of both
    routes live under ``wall`` — the fused route defers staging into
    the compute window, so its fraction is the one the tentpole claim
    is about (> ~0.94 on the committed non-smoke run)."""
    import numpy as np

    from repro.kernels.fused_cg import fused_pass_traffic

    spec = "erasure(nvm-prd x6+2p)"
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)
    campaign = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=at),))

    states, reports, walls = {}, {}, {}
    be = None
    for label, fused in (("ref", False), ("fused", True)):
        solver = make_solver("pcg", op, pre)
        be = make_backend(spec, op, solver=solver)
        tracer = Tracer()
        t0 = time.perf_counter()
        st, rep, _ = solve(solver, op, b, pre,
                           SolveConfig(tol=tol, maxiter=20000,
                                       persist_mode="overlap",
                                       fused_persist=fused,
                                       tracer=tracer),
                           backend=be, failures=campaign)
        walls[label] = time.perf_counter() - t0
        check_trace_report(tracer, rep)
        states[label] = np.asarray(st.x)
        reports[label] = rep

    itemsize = int(np.dtype(b.dtype).itemsize)
    ref_rep, fused_rep = reports["ref"], reports["fused"]
    return {
        "spec": spec,
        "geometry": {
            "k_data": be.k_data,
            "nparity": be.nparity,
            "chunk_values": be.chunk,
            "itemsize": itemsize,
            # one stripe encode reads the K data chunks of every block
            # and emits P parity chunks, per schema vector per event
            "encode_read_bytes_per_event":
                be.nblocks * be.k_data * be.chunk * itemsize,
            "parity_bytes_per_event":
                be.nblocks * be.nparity * be.chunk * itemsize,
            "fused_pass": fused_pass_traffic(op.n, itemsize, be.k_data,
                                             be.nparity),
        },
        "counts": {
            # the tentpole's exactness claim, recorded in the artifact:
            # both routes produce the same final iterate, bit for bit
            "bit_identical": bool(np.array_equal(states["ref"],
                                                 states["fused"])),
            "counts_match_ref": bool(
                ref_rep.iterations == fused_rep.iterations
                and ref_rep.persist_events == fused_rep.persist_events
                and ref_rep.persist_aborts == fused_rep.persist_aborts),
            "iterations": fused_rep.iterations,
            "persist_events": fused_rep.persist_events,
            "persist_aborts": fused_rep.persist_aborts,
        },
        "wall": {
            "hidden_fraction_ref": ref_rep.persist_hidden_fraction,
            "hidden_fraction_fused": fused_rep.persist_hidden_fraction,
            "iterations_per_s_ref":
                ref_rep.iterations / max(walls["ref"], 1e-12),
            "iterations_per_s_fused":
                fused_rep.iterations / max(walls["fused"], 1e-12),
        },
    }


def _sharded_rows(grid, tol: float, at: int) -> dict:
    """The per-shard persist/recovery rows (DESIGN.md §10): for each
    feasible device-shard count, an overlapped solve with a shard-kill
    campaign, reporting the bytes it moved (deterministic — persist
    traffic per shard, and a recovery fetch that moves only the lost
    shard's slots) and the hidden fraction at that shard count
    (wall-clock, outside the determinism contract)."""
    import jax
    import numpy as np

    from repro.core.state import PCG_SCHEMA
    from repro.distributed.sharding import shard_problem

    # a dedicated nblocks=8 layout so every SHARD_COUNTS entry divides
    op, b = make_poisson_problem(*grid, nblocks=8)
    pre = JacobiPreconditioner(op)
    slot = PCG_SCHEMA.slot_nbytes(op.partition.block_size,
                                  np.dtype(b.dtype))
    rows = {}
    for nshards in SHARD_COUNTS:
        if jax.device_count() < nshards:
            continue    # run.py --json fakes 8 host devices; in-process
                        # callers may only manage the 1-shard row
        sop, sb = shard_problem(op, b, nshards)
        solver = make_solver("pcg", sop, pre)
        be = make_backend("nvm-prd", op, solver=solver)
        campaign = FailureCampaign((
            FailureEvent(shard=0, at_iteration=at),))
        _, rep, _ = solve(solver, sop, sb, pre,
                          SolveConfig(tol=tol, maxiter=20000,
                                      persist_mode="overlap"),
                          backend=be, failures=campaign)
        rows[str(nshards)] = {
            "bytes": {
                "blocks_per_shard": 8 // nshards,
                "slot_nbytes": slot,
                "persist_bytes": rep.persist_bytes,
                "recovery_fetch_bytes": rep.recovery_fetch_bytes,
                "recovery_fetch_bytes_by_shard": {
                    str(s): n for s, n in
                    sorted(rep.recovery_fetch_bytes_by_shard.items())},
            },
            "wall": {"hidden_fraction": rep.persist_hidden_fraction},
        }
    return rows


def _service_rows(seed: int, smoke: bool) -> dict:
    """The multi-tenant service rows (DESIGN.md §12): sustained seeded
    load through :class:`~repro.serving.SolveService`, with and without
    per-tenant failure campaigns.  Queue statistics are in deterministic
    service steps, so everything under ``counts`` is a pure function of
    ``(seed, smoke)``; only throughput lives under ``wall``."""
    from repro import api

    nrequests = 4 if smoke else 8
    lanes = 2   # narrow on purpose: sustained load must queue
    rows: dict = {"trace": {"seed": int(seed), "requests": nrequests,
                            "lanes": lanes}}
    for label, rate in (("no_failures", 0.0), ("with_failures", 1.0)):
        reqs = api.generate_request_trace(seed, nrequests=nrequests,
                                          failure_rate=rate,
                                          survivable_only=True)
        svc = api.SolveService(api.ServiceConfig(lanes=lanes,
                                                 max_queue=2 * nrequests))
        t0 = time.perf_counter()
        tickets = svc.replay(reqs)
        wall_s = time.perf_counter() - t0
        done = [t for t in tickets.values() if t.accepted]
        waits = svc.metrics.histogram("service.queue_wait_steps")
        occupancy = svc.metrics.histogram("service.batch_occupancy")
        rows[label] = {
            "counts": {
                "requests": len(reqs),
                "completed": svc.metrics.counter_value("service.completed"),
                "rejected": svc.metrics.counter_value("service.rejected"),
                "converged": sum(1 for t in done
                                 if t.result.report.converged),
                "failures_recovered": sum(
                    t.result.report.failures_recovered for t in done),
                "service_steps": svc.now,
                "queue_wait_steps_p50": waits.percentile(50),
                "queue_wait_steps_p99": waits.percentile(99),
                "batch_occupancy_mean": occupancy.mean,
            },
            "wall": {
                "elapsed_s": wall_s,
                "solves_per_s": len(done) / max(wall_s, 1e-12),
            },
        }
    return rows


def rows(seed: int = 0):
    """CSV view for the default ``run.py`` harness: the headline
    quantities per spec (the JSON document is the primary artifact)."""
    doc = build(seed=seed)
    out = []
    for spec, entry in doc["specs"].items():
        out.append((f"trajectory_{spec}_exposed_us_per_iter_sync",
                    entry["modeled"]["exposed_persist_s_per_iter"] * 1e6,
                    "modeled critical-path persist cost, sync pipeline"))
        out.append((f"trajectory_{spec}_hidden_fraction",
                    entry["wall"]["hidden_fraction"],
                    "overlap pipeline, wall-clock dependent"))
        out.append((f"trajectory_{spec}_recovery_latency_us",
                    entry["wall"]["recovery_latency_s"] * 1e6,
                    "traced recovery.fetch + recovery.reconstruct wall"))
    for n, entry in doc["sharded"].items():
        out.append((f"trajectory_sharded{n}_recovery_fetch_bytes",
                    entry["bytes"]["recovery_fetch_bytes"],
                    "bytes a shard-kill recovery moves (lost shard only)"))
        out.append((f"trajectory_sharded{n}_hidden_fraction",
                    entry["wall"]["hidden_fraction"],
                    f"overlap pipeline at {n} shard(s), wall-clock "
                    f"dependent"))
    for label in ("no_failures", "with_failures"):
        entry = doc["service"][label]
        out.append((f"trajectory_service_{label}_queue_wait_p99_steps",
                    entry["counts"]["queue_wait_steps_p99"],
                    "multi-tenant service queue wait, deterministic steps"))
        out.append((f"trajectory_service_{label}_solves_per_s",
                    entry["wall"]["solves_per_s"],
                    "multi-tenant service throughput, wall-clock dependent"))
    pk = doc["persist_kernels"]
    out.append(("trajectory_persist_hidden_fraction_ref",
                pk["wall"]["hidden_fraction_ref"],
                "numpy persist route on x6+2p, wall-clock dependent"))
    out.append(("trajectory_persist_hidden_fraction_fused",
                pk["wall"]["hidden_fraction_fused"],
                "fused persist route on x6+2p, wall-clock dependent"))
    return out
