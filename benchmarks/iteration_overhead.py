"""Wall-clock persistence overhead per PCG iteration (crash-free run) on
this container's CPU, plus recovery-path timing: the end-to-end version
of Figs. 9/10 on real (simulated-NVM) execution.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    FailurePlan,
    InMemoryESR,
    JacobiPreconditioner,
    NVMESRHomogeneous,
    NVMESRPRD,
    PCGConfig,
    make_poisson_problem,
    solve,
)


def _run(backend=None, failures=(), grid=(32, 16, 16), nblocks=8):
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)
    # warm the jit caches so wall time measures the steady state
    solve(op, b, pre, PCGConfig(tol=1e-2, maxiter=3))
    t0 = time.perf_counter()
    _, rep, _ = solve(op, b, pre, PCGConfig(tol=1e-10), backend=backend,
                      failures=list(failures))
    wall = time.perf_counter() - t0
    return wall, rep


def rows():
    out = []
    base_wall, base_rep = _run()
    per_iter = base_wall / max(base_rep.iterations, 1)
    out.append(("pcg_plain_us_per_iter", per_iter * 1e6,
                f"{base_rep.iterations} iters to 1e-10"))
    mk = {
        "esr_inmemory": lambda op_n, bs: InMemoryESR(op_n, bs, np.float64),
        "nvm_homogeneous": lambda op_n, bs: NVMESRHomogeneous(op_n, bs, np.float64),
        "nvm_prd": lambda op_n, bs: NVMESRPRD(op_n, bs, np.float64),
    }
    op, _ = make_poisson_problem(32, 16, 16, nblocks=8)
    for name, f in mk.items():
        be = f(op.nblocks, op.partition.block_size)
        wall, rep = _run(backend=be)
        out.append((f"pcg_{name}_us_per_iter", wall / max(rep.iterations, 1) * 1e6,
                    f"modeled persist {rep.persist_cost_s*1e3:.2f}ms total"))
    # recovery path
    be = NVMESRPRD(op.nblocks, op.partition.block_size, np.float64)
    wall, rep = _run(backend=be, failures=[FailurePlan(20, (2, 5))])
    out.append(("pcg_nvm_prd_recovery_run_us_per_iter",
                wall / max(rep.iterations, 1) * 1e6,
                f"recovered={rep.failures_recovered} wasted={rep.wasted_iterations}"))
    return out
