"""ESR vs NVM-ESR on the production mesh: collective bytes + device-RAM
footprint from the compiled solver step (the structural version of the
paper's memory/time claims, per DESIGN.md §5).

Reads results/dryrun.jsonl when the full sweep has run; otherwise spawns
a subprocess with a small 8-device host mesh (this process must keep
seeing 1 device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax
from repro.core.spmv import lower_pcg_step
from repro.launch.mesh import compat_make_mesh
from repro.launch.roofline import analyze
mesh = compat_make_mesh((2,2,2), ("pod","data","model"))
out = {}
for mode in ("nvm", "inmemory"):
    compiled = lower_pcg_step(mesh, 64, 64, 64, esr_mode=mode).compile()
    r = analyze(compiled, 8)
    ma = compiled.memory_analysis()
    out[mode] = {
        "coll_bytes": r.coll_bytes,
        "coll_by_kind": r.coll_by_kind,
        "dev_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes),
    }
print(json.dumps(out))
"""


def _from_dryrun():
    path = "results/dryrun.jsonl"
    if not os.path.exists(path):
        return None
    rows = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("arch") == "poisson_pcg" and r["mesh"] == "16x16":
            rows[r["shape"]] = r
    if {"pcg_1g", "pcg_1g_esr"} <= set(rows):
        return rows
    return None


def _persist_bandwidth_rows():
    """The fused persist-bandwidth term (DESIGN.md §13): what share of
    the fused update+staging pass's HBM traffic is persist staging, and
    how many re-read bytes the fusion removes, on the paper's x6+2p
    stripe at the bench solve size."""
    from repro.kernels.fused_cg import fused_pass_traffic

    t = fused_pass_traffic(n=64 * 64 * 64, itemsize=8, k_data=6, nparity=2)
    return [
        ("solver_fused_pass_total_bytes", t["total_bytes"],
         "fused update+staging HBM bytes per pass (x6+2p)"),
        ("solver_persist_bw_fraction", t["persist_bw_fraction"],
         "share of the fused pass spent on persist staging"),
        ("solver_fused_saved_read_bytes", t["unfused_extra_read_bytes"],
         "vector re-read a standalone staging pass would add"),
    ]


def rows():
    out = _persist_bandwidth_rows()
    dr = _from_dryrun()
    if dr is not None:
        nvm, esr = dr["pcg_1g"], dr["pcg_1g_esr"]
        out.append(("solver_nvm_coll_bytes_per_chip",
                    nvm["roofline"]["coll_bytes_per_chip"], "production mesh"))
        out.append(("solver_esr_coll_bytes_per_chip",
                    esr["roofline"]["coll_bytes_per_chip"], "production mesh"))
        out.append(("solver_esr_extra_allgather_bytes",
                    esr["coll_by_kind"].get("all-gather", 0)
                    - nvm["coll_by_kind"].get("all-gather", 0),
                    "the redundancy all-to-all of Algorithm 2"))
        out.append(("solver_esr_dev_ram_x",
                    esr["memory"]["peak_bytes"] / max(nvm["memory"]["peak_bytes"], 1),
                    "peak device RAM blow-up of in-memory ESR"))
        return out
    env = dict(os.environ)
    # prepend, never overwrite: the tier-1 command exports
    # PYTHONPATH=src:$PYTHONPATH and the subprocess must still see the
    # caller's entries (site-installed deps, sitecustomize, ...)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src"] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SUB], capture_output=True,
                         text=True, env=env, check=True)
    data = json.loads(res.stdout.strip().splitlines()[-1])
    out.append(("solver_nvm_coll_bytes", data["nvm"]["coll_bytes"], "8-dev mesh"))
    out.append(("solver_esr_coll_bytes", data["inmemory"]["coll_bytes"], "8-dev mesh"))
    out.append(("solver_esr_dev_ram_x",
                data["inmemory"]["dev_bytes"] / max(data["nvm"]["dev_bytes"], 1),
                "peak device RAM blow-up of in-memory ESR"))
    return out
