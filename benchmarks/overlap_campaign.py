"""Overlapped persistence + failure campaigns across the backend matrix.

Two views the paper-era benchmarks don't cover:

1. **Pipeline comparison** — the same PCG persistence schedule through
   the synchronous host-pull baseline and the overlapped begin/commit
   pipeline (DESIGN.md §6).  Reported per backend: exposed persist cost
   per event for both modes and the persist-hidden fraction (the share of
   modeled commit cost hidden behind the next iteration's compute).

2. **Campaign resilience** — the acceptance scenario of ISSUE 2: a
   mid-burst failure under ESRP (the staged persist is torn away, falling
   back to the previous durable run), an overlapping second failure
   landing during the in-flight recovery, and a repeated failure of an
   already-failed block.  Reported per backend: recovered events,
   recovery restarts, wasted iterations, and convergence.

3. **Replicated PRD** (ISSUE 3) — ``replicated(nvm-prd x2)`` vs a single
   PRD node: the persist-cost overhead of RAID-1 mirroring in both
   pipelines, the hidden fraction the overlap window still buys, and a
   campaign whose event crashes one PRD node *itself* alongside two
   compute blocks (recovered from the surviving mirror).

4. **Erasure-coded stripe** (ISSUE 4) — ``erasure(nvm-prd x4+p)`` vs
   the single PRD node and the 2x mirror: the *storage* overhead of
   XOR parity ((K+1)/K = 1.25x, strictly below the mirror's 2.0x — the
   footprint-vs-resilience trade-off of the paper applied to the
   redundancy layer), its persist-cost overhead in both pipelines, and
   the same PRD-node-loss campaign recovered in degraded mode from
   parity.  A planner row records that the campaign the stripe cannot
   survive (two PRD losses feeding a recovery) is rejected before
   iteration 0.

Smoke mode (``REPRO_BENCH_SMOKE=1`` or ``run.py --smoke``) shrinks the
grid so the sweep doubles as a CI dry run (including the composite
backend path).
"""
from __future__ import annotations

import os

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.solvers import (
    BACKENDS,
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    UnsurvivableCampaignError,
    make_backend,
    make_solver,
    solve,
)


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def rows():
    out = []
    if _smoke():
        grid, nblocks, tol = (8, 8, 8), 4, 1e-8
    else:
        grid, nblocks, tol = (32, 16, 16), 8, 1e-10
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)

    # ---- pipeline comparison: sync baseline vs overlapped commit ----
    for bname in sorted(BACKENDS):
        reps = {}
        for mode in ("sync", "overlap"):
            solver = make_solver("pcg", op, pre)
            be = make_backend(bname, op, solver=solver)
            _, rep, _ = solve(solver, op, b, pre,
                              SolveConfig(tol=tol, maxiter=20000,
                                          persist_mode=mode),
                              backend=be)
            reps[mode] = rep
        for mode, rep in reps.items():
            exposed = rep.persist_exposed_s / max(rep.persist_events, 1)
            out.append((f"overlap_{bname}_{mode}_exposed_us_per_event",
                        exposed * 1e6,
                        f"{rep.persist_events} events, modeled critical path"))
        out.append((f"overlap_{bname}_hidden_fraction",
                    reps["overlap"].persist_hidden_fraction,
                    "share of commit cost hidden behind compute"))
        out.append((f"overlap_{bname}_stage_us_per_event",
                    reps["overlap"].persist_stage_s * 1e6
                    / max(reps["overlap"].persist_events, 1),
                    "staging copy left on the critical path"))

    # ---- campaign resilience: mid-burst + overlapping + repeated ----
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=6),   # mid-burst (T=5)
        FailureEvent(blocks=(0,), during_recovery_at=6),  # overlapping
        FailureEvent(blocks=(1,), at_iteration=12),    # repeated block
    ))
    for bname in sorted(BACKENDS):
        solver = make_solver("pcg", op, pre)
        be = make_backend(bname, op, solver=solver)
        _, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=tol, maxiter=20000,
                                      persistence_period=5,
                                      persist_mode="overlap"),
                          backend=be, failures=campaign)
        out.append((f"campaign_{bname}_recovered", rep.failures_recovered,
                    f"restarts={rep.recovery_restarts} "
                    f"converged={rep.converged}"))
        out.append((f"campaign_{bname}_wasted_iterations",
                    rep.wasted_iterations,
                    f"rollback cost over {rep.iterations} iterations"))

    # ---- replicated PRD: mirroring overhead + PRD-node-loss campaign ----
    repl_name = "replicated(nvm-prd x2)"
    repl_reps = {}
    for mode in ("sync", "overlap"):
        reps = {}
        for bname in ("nvm-prd", repl_name):
            solver = make_solver("pcg", op, pre)
            be = make_backend(bname, op, solver=solver)
            _, rep, _ = solve(solver, op, b, pre,
                              SolveConfig(tol=tol, maxiter=20000,
                                          persist_mode=mode),
                              backend=be)
            reps[bname] = rep
        repl_reps[mode] = reps[repl_name]
        out.append((f"replicated_prd_x2_{mode}_persist_overhead",
                    reps[repl_name].persist_cost_s
                    / max(reps["nvm-prd"].persist_cost_s, 1e-30),
                    "mirrored persist cost / single-PRD cost (~2x)"))
        out.append((f"replicated_prd_x2_{mode}_exposed_us_per_event",
                    reps[repl_name].persist_exposed_s * 1e6
                    / max(reps[repl_name].persist_events, 1),
                    "critical-path cost per event with two mirrors"))
    out.append(("replicated_prd_x2_hidden_fraction",
                repl_reps["overlap"].persist_hidden_fraction,
                "share of the DOUBLED commit cost still hidden"))

    solver = make_solver("pcg", op, pre)
    be = make_backend(repl_name, op, solver=solver)
    prd_campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=8, prd=True),))
    _, rep, _ = solve(solver, op, b, pre,
                      SolveConfig(tol=tol, maxiter=20000,
                                  persist_mode="overlap"),
                      backend=be, failures=prd_campaign)
    out.append(("replicated_prd_x2_prdloss_recovered", rep.failures_recovered,
                f"PRD node + 2 blocks crashed; storage_failures="
                f"{rep.storage_failures} converged={rep.converged}"))

    # ---- erasure stripe: footprint + cost vs the mirror (ISSUE 4) ----
    er_name = "erasure(nvm-prd x4+p)"
    solver = make_solver("pcg", op, pre)
    single_be = make_backend("nvm-prd", op, solver=solver)
    repl_be = make_backend(repl_name, op, solver=solver)
    er_be = make_backend(er_name, op, solver=solver)
    out.append(("erasure_x4p_storage_overhead",
                er_be.nvm_values() / single_be.nvm_values(),
                f"stripe values / single-PRD values; mirror pays "
                f"{repl_be.nvm_values() / single_be.nvm_values():.2f}x for "
                f"the same single-PRD-loss guarantee"))
    er_reps = {}
    for mode in ("sync", "overlap"):
        reps = {}
        for bname in ("nvm-prd", er_name):
            solver = make_solver("pcg", op, pre)
            be = make_backend(bname, op, solver=solver)
            _, rep, _ = solve(solver, op, b, pre,
                              SolveConfig(tol=tol, maxiter=20000,
                                          persist_mode=mode),
                              backend=be)
            reps[bname] = rep
        er_reps[mode] = reps[er_name]
        out.append((f"erasure_x4p_{mode}_persist_overhead",
                    reps[er_name].persist_cost_s
                    / max(reps["nvm-prd"].persist_cost_s, 1e-30),
                    "striped persist cost / single-PRD cost "
                    "(K+1 smaller puts)"))
        out.append((f"erasure_x4p_{mode}_exposed_us_per_event",
                    reps[er_name].persist_exposed_s * 1e6
                    / max(reps[er_name].persist_events, 1),
                    "critical-path cost per event across the stripe"))
    out.append(("erasure_x4p_hidden_fraction",
                er_reps["overlap"].persist_hidden_fraction,
                "share of the striped commit cost still hidden"))

    solver = make_solver("pcg", op, pre)
    be = make_backend(er_name, op, solver=solver)
    _, rep, _ = solve(solver, op, b, pre,
                      SolveConfig(tol=tol, maxiter=20000,
                                  persist_mode="overlap"),
                      backend=be, failures=prd_campaign)
    out.append(("erasure_x4p_prdloss_recovered", rep.failures_recovered,
                f"stripe node + 2 blocks crashed; degraded fetch rebuilt "
                f"the lost chunks from parity; storage_failures="
                f"{rep.storage_failures} converged={rep.converged}"))

    # planner: the campaign the stripe provably cannot survive (two PRD
    # losses feeding recoveries) is rejected before iteration 0
    double_loss = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=6, prd=True),
        FailureEvent(blocks=(2,), at_iteration=10, prd=True),
    ))
    solver = make_solver("pcg", op, pre)
    be = make_backend(er_name, op, solver=solver)
    try:
        solve(solver, op, b, pre, SolveConfig(tol=tol, maxiter=20000),
              backend=be, failures=double_loss)
        rejected = 0
    except UnsurvivableCampaignError:
        rejected = 1
    out.append(("erasure_x4p_planner_rejects_double_prd_loss", rejected,
                "plan_campaign refused before iteration 0 (1 = rejected)"))
    return out
