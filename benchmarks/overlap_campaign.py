"""Overlapped persistence + failure campaigns across the backend matrix.

Two views the paper-era benchmarks don't cover:

1. **Pipeline comparison** — the same PCG persistence schedule through
   the synchronous host-pull baseline and the overlapped begin/commit
   pipeline (DESIGN.md §6).  Reported per backend: exposed persist cost
   per event for both modes and the persist-hidden fraction (the share of
   modeled commit cost hidden behind the next iteration's compute).

2. **Campaign resilience** — the acceptance scenario of ISSUE 2: a
   mid-burst failure under ESRP (the staged persist is torn away, falling
   back to the previous durable run), an overlapping second failure
   landing during the in-flight recovery, and a repeated failure of an
   already-failed block.  Reported per backend: recovered events,
   recovery restarts, wasted iterations, and convergence.

3. **Replicated PRD** (ISSUE 3) — ``replicated(nvm-prd x2)`` vs a single
   PRD node: the persist-cost overhead of RAID-1 mirroring in both
   pipelines, the hidden fraction the overlap window still buys, and a
   campaign whose event crashes one PRD node *itself* alongside two
   compute blocks (recovered from the surviving mirror).

4. **Erasure-coded stripes** (ISSUE 4/5) — the erasure section is
   parameterized over ``(K, P)``: ``erasure(nvm-prd x4+p)`` (XOR,
   distance 2) and ``erasure(nvm-prd x6+2p)`` (GF(256) Reed-Solomon
   P+Q, distance 3) vs the single PRD node and the mirrors.  Reported
   per stripe: the *storage* overhead ((K+P)/K, strictly below the
   (P+1)x mirror buying the same loss budget), persist-cost overhead in
   both pipelines, the rotating-parity write spread (max-min parity
   writes per child; rotation keeps it <= 1), a campaign killing P
   storage children recovered in degraded mode, and a planner row
   recording that the campaign the stripe cannot survive (P+1 storage
   losses feeding a recovery) is rejected before iteration 0.

Smoke mode (``REPRO_BENCH_SMOKE=1`` or ``run.py --smoke``) shrinks the
grid so the sweep doubles as a CI dry run (including the composite
backend path).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.solvers import (
    BACKENDS,
    FailureCampaign,
    FailureEvent,
    SolveConfig,
    UnsurvivableCampaignError,
    make_backend,
    make_solver,
    solve,
)


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def rows():
    out = []
    if _smoke():
        grid, nblocks, tol = (8, 8, 8), 4, 1e-8
    else:
        grid, nblocks, tol = (32, 16, 16), 8, 1e-10
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)

    # ---- pipeline comparison: sync baseline vs overlapped commit ----
    for bname in sorted(BACKENDS):
        reps = {}
        for mode in ("sync", "overlap"):
            solver = make_solver("pcg", op, pre)
            be = make_backend(bname, op, solver=solver)
            _, rep, _ = solve(solver, op, b, pre,
                              SolveConfig(tol=tol, maxiter=20000,
                                          persist_mode=mode),
                              backend=be)
            reps[mode] = rep
        for mode, rep in reps.items():
            exposed = rep.persist_exposed_s / max(rep.persist_events, 1)
            out.append((f"overlap_{bname}_{mode}_exposed_us_per_event",
                        exposed * 1e6,
                        f"{rep.persist_events} events, modeled critical path"))
        out.append((f"overlap_{bname}_hidden_fraction",
                    reps["overlap"].persist_hidden_fraction,
                    "share of commit cost hidden behind compute"))
        out.append((f"overlap_{bname}_stage_us_per_event",
                    reps["overlap"].persist_stage_s * 1e6
                    / max(reps["overlap"].persist_events, 1),
                    "staging copy left on the critical path"))

    # ---- campaign resilience: mid-burst + overlapping + repeated ----
    campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=6),   # mid-burst (T=5)
        FailureEvent(blocks=(0,), during_recovery_at=6),  # overlapping
        FailureEvent(blocks=(1,), at_iteration=12),    # repeated block
    ))
    for bname in sorted(BACKENDS):
        solver = make_solver("pcg", op, pre)
        be = make_backend(bname, op, solver=solver)
        _, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=tol, maxiter=20000,
                                      persistence_period=5,
                                      persist_mode="overlap"),
                          backend=be, failures=campaign)
        out.append((f"campaign_{bname}_recovered", rep.failures_recovered,
                    f"restarts={rep.recovery_restarts} "
                    f"converged={rep.converged}"))
        out.append((f"campaign_{bname}_wasted_iterations",
                    rep.wasted_iterations,
                    f"rollback cost over {rep.iterations} iterations"))

    # ---- replicated PRD: mirroring overhead + PRD-node-loss campaign ----
    repl_name = "replicated(nvm-prd x2)"
    repl_reps = {}
    for mode in ("sync", "overlap"):
        reps = {}
        for bname in ("nvm-prd", repl_name):
            solver = make_solver("pcg", op, pre)
            be = make_backend(bname, op, solver=solver)
            _, rep, _ = solve(solver, op, b, pre,
                              SolveConfig(tol=tol, maxiter=20000,
                                          persist_mode=mode),
                              backend=be)
            reps[bname] = rep
        repl_reps[mode] = reps[repl_name]
        out.append((f"replicated_prd_x2_{mode}_persist_overhead",
                    reps[repl_name].persist_cost_s
                    / max(reps["nvm-prd"].persist_cost_s, 1e-30),
                    "mirrored persist cost / single-PRD cost (~2x)"))
        out.append((f"replicated_prd_x2_{mode}_exposed_us_per_event",
                    reps[repl_name].persist_exposed_s * 1e6
                    / max(reps[repl_name].persist_events, 1),
                    "critical-path cost per event with two mirrors"))
    out.append(("replicated_prd_x2_hidden_fraction",
                repl_reps["overlap"].persist_hidden_fraction,
                "share of the DOUBLED commit cost still hidden"))

    solver = make_solver("pcg", op, pre)
    be = make_backend(repl_name, op, solver=solver)
    prd_campaign = FailureCampaign((
        FailureEvent(blocks=(1, 2), at_iteration=8, prd=True),))
    _, rep, _ = solve(solver, op, b, pre,
                      SolveConfig(tol=tol, maxiter=20000,
                                  persist_mode="overlap"),
                      backend=be, failures=prd_campaign)
    out.append(("replicated_prd_x2_prdloss_recovered", rep.failures_recovered,
                f"PRD node + 2 blocks crashed; storage_failures="
                f"{rep.storage_failures} converged={rep.converged}"))

    # ---- erasure stripes: footprint + cost vs mirrors (ISSUE 4/5),
    # parameterized over (K data children, P parity children) ----
    for k_data, nparity in ((4, 1), (6, 2)):
        suffix = "p" if nparity == 1 else f"{nparity}p"
        er_name = f"erasure(nvm-prd x{k_data}+{suffix})"
        tag = f"x{k_data}p" if nparity == 1 else f"x{k_data}p{nparity}"
        # the mirror buying the same storage-loss budget: P+1 copies
        mirror_name = f"replicated(nvm-prd x{nparity + 1})"
        solver = make_solver("pcg", op, pre)
        single_be = make_backend("nvm-prd", op, solver=solver)
        mirror_be = make_backend(mirror_name, op, solver=solver)
        er_be = make_backend(er_name, op, solver=solver)
        out.append((f"erasure_{tag}_storage_overhead",
                    er_be.nvm_values() / single_be.nvm_values(),
                    f"stripe values / single-PRD values; {mirror_name} pays "
                    f"{mirror_be.nvm_values() / single_be.nvm_values():.2f}x "
                    f"for the same {nparity}-storage-loss budget"))
        er_reps = {}
        for mode in ("sync", "overlap"):
            reps = {}
            for bname in ("nvm-prd", er_name):
                solver = make_solver("pcg", op, pre)
                be = make_backend(bname, op, solver=solver)
                _, rep, _ = solve(solver, op, b, pre,
                                  SolveConfig(tol=tol, maxiter=20000,
                                              persist_mode=mode),
                                  backend=be)
                reps[bname] = rep
            er_reps[mode] = reps[er_name]
            out.append((f"erasure_{tag}_{mode}_persist_overhead",
                        reps[er_name].persist_cost_s
                        / max(reps["nvm-prd"].persist_cost_s, 1e-30),
                        "striped persist cost / single-PRD cost "
                        f"(K+{nparity} smaller puts)"))
            out.append((f"erasure_{tag}_{mode}_exposed_us_per_event",
                        reps[er_name].persist_exposed_s * 1e6
                        / max(reps[er_name].persist_events, 1),
                        "critical-path cost per event across the stripe"))
        out.append((f"erasure_{tag}_hidden_fraction",
                    er_reps["overlap"].persist_hidden_fraction,
                    "share of the striped commit cost still hidden"))

        # rotating parity: per-child parity-write spread over a probe
        # session (RAID-5/6 proper — rotation keeps max-min <= 1)
        solver = make_solver("pcg", op, pre)
        be = make_backend(er_name, op, solver=solver)
        session = be.open_session(solver.schema)
        zeros = {v: np.zeros(op.n) for v in solver.schema.vectors}
        zscal = {s: 0.0 for s in solver.schema.scalars}
        for k in range(4 * (k_data + nparity) + 3):
            session.persist(k, zscal, zeros)
        out.append((f"erasure_{tag}_parity_write_spread",
                    max(session.parity_writes) - min(session.parity_writes),
                    f"max-min parity writes per child over "
                    f"{4 * (k_data + nparity) + 3} stripes "
                    f"(counts: {session.parity_writes})"))

        # campaign: P storage children + 2 compute blocks crash; the
        # stripe recovers in degraded mode from the surviving parity
        loss_events = tuple(
            FailureEvent(blocks=(), at_iteration=7 + i, prd=True)
            for i in range(nparity - 1)) + (
            FailureEvent(blocks=(1, 2), at_iteration=8, prd=True),)
        solver = make_solver("pcg", op, pre)
        be = make_backend(er_name, op, solver=solver)
        _, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=tol, maxiter=20000,
                                      persist_mode="overlap"),
                          backend=be, failures=FailureCampaign(loss_events))
        out.append((f"erasure_{tag}_storage_loss_recovered",
                    rep.failures_recovered,
                    f"{nparity} stripe node(s) + 2 blocks crashed; degraded "
                    f"fetch rebuilt the lost chunks from parity; "
                    f"storage_failures={rep.storage_failures} "
                    f"converged={rep.converged}"))

        # planner: the campaign the stripe provably cannot survive
        # (P+1 storage losses feeding recoveries) is rejected before
        # iteration 0
        over_budget = FailureCampaign(tuple(
            FailureEvent(blocks=(1,), at_iteration=6 + 2 * i, prd=True)
            for i in range(nparity + 1)))
        solver = make_solver("pcg", op, pre)
        be = make_backend(er_name, op, solver=solver)
        try:
            solve(solver, op, b, pre, SolveConfig(tol=tol, maxiter=20000),
                  backend=be, failures=over_budget)
            rejected = 0
        except UnsurvivableCampaignError:
            rejected = 1
        out.append((f"erasure_{tag}_planner_rejects_"
                    f"{nparity + 1}_prd_losses", rejected,
                    "plan_campaign refused before iteration 0 "
                    "(1 = rejected)"))

    # ---- the cheapest-spec advisor (ISSUE 5): for the double-loss
    # campaign, the K+2p stripe beats the triple mirror on footprint ----
    from repro.solvers import advise_spec

    double_loss = FailureCampaign((
        FailureEvent(blocks=(1,), at_iteration=6, prd=True),
        FailureEvent(blocks=(2,), at_iteration=10, prd=True),
    ))
    solver = make_solver("pcg", op, pre)
    candidates = {
        name: make_backend(name, op, solver=solver)
        for name in ("nvm-prd", "replicated(nvm-prd x2)",
                     "replicated(nvm-prd x3)", "erasure(nvm-prd x4+p)",
                     "erasure(nvm-prd x6+2p)")
    }
    advice = advise_spec(double_loss, candidates, probe_values=op.n)
    chosen = advice.ranked[0] if advice.ranked else None
    out.append(("advisor_double_loss_picks_k2p_stripe",
                int(advice.chosen == "erasure(nvm-prd x6+2p)"),
                f"chosen={advice.chosen} "
                f"(storage {chosen.storage_values if chosen else '-'} values "
                f"vs survivors {[r.spec for r in advice.ranked]})"))
    return out
