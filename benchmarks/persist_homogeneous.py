"""Paper Fig. 9: time overhead of one persistence/redundancy iteration in
the HOMOGENEOUS architecture, per backend:

  - in-memory ESR (peer-RAM redundancy over the network)
  - NVM-ESR via PMDK-pool over local NVM      (pmemobj_persist path)
  - NVM-ESR via local MPI window over NVM     (fence_persist path)
  - NVM-ESR via local PMFS                    (ext4-DAX-like: NVM tier)
  - local SATA-SSD reference

Fixed local vector of 176,400 fp64 entries per process (the paper's
setting).  Reported time is the calibrated model (paper-cluster
constants); wall time of the simulation is also measured.  Local
persistence is embarrassingly parallel across nodes, so homogeneous
NVM-ESR cost is flat in process count, while in-memory ESR grows once
redundancy crosses node boundaries (the paper's crossover >32 procs).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.esr import InMemoryESR
from repro.core.nvm_esr import NVMESRHomogeneous
from repro.nvm.pmdk import PmemPool
from repro.nvm.store import NETWORK_SPECS, Store, Tier, TIER_SPECS
from repro.nvm.windows import Window

LOCAL_N = 176_400  # fp64 entries per process (paper Fig. 9 setting)


def _payload(nprocs, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(nprocs * LOCAL_N)


def esr_inmemory_cost(nprocs: int, seed: int = 0) -> float:
    """Full-fault-tolerance redundancy iteration (modeled)."""
    nprocs = max(nprocs, 2)  # redundancy needs at least one peer
    be = InMemoryESR(nprocs, LOCAL_N, np.float64)
    cost = be.persist_set(1, {"beta": 0.5}, {"p": _payload(nprocs, seed)})
    return cost / nprocs  # per-process view


def nvm_homog_cost(nprocs: int, tier: Tier, seed: int = 0) -> float:
    be = NVMESRHomogeneous(min(nprocs, 4), LOCAL_N, np.float64, tier=tier)
    # wall cost is the max over blocks (parallel nodes): measure 4, it's flat
    return be.persist_set(1, {"beta": 0.5},
                          {"p": _payload(min(nprocs, 4), seed)})


def local_window_cost(nprocs: int) -> float:
    """Local MPI window over NVM: put + fence_persist (per process)."""
    payload = np.zeros(LOCAL_N, np.float64).tobytes()
    store = Store(len(payload) + 64, Tier.NVM)
    win = Window(store, network="local")
    win.lock(0)
    c = win.put(0, 0, payload)
    c += win.unlock(0, persist=True)
    return c


def rows(seed: int = 0):
    out = []
    bytes_per_proc = LOCAL_N * 8
    for nprocs in (1, 4, 16, 32, 64, 128):
        esr = esr_inmemory_cost(nprocs, seed)
        out.append((f"fig9_esr_inmemory_p{nprocs}", esr * 1e6, "per-proc modeled us"))
    for name, tier in (("pmdk_nvm", Tier.NVM), ("pmfs_nvm", Tier.NVM),
                       ("local_ssd", Tier.SSD)):
        t0 = time.perf_counter()
        c = nvm_homog_cost(4, tier, seed)
        wall = time.perf_counter() - t0
        out.append((f"fig9_nvmesr_{name}", c * 1e6,
                    f"modeled us, flat in nprocs; sim wall {wall*1e3:.1f}ms"))
    out.append(("fig9_nvmesr_local_window", local_window_cost(1) * 1e6,
                "modeled us (put+fence_persist)"))
    # sanity derivations the paper asserts
    nvm = nvm_homog_cost(4, Tier.NVM, seed)
    ssd = nvm_homog_cost(4, Tier.SSD, seed)
    esr128 = esr_inmemory_cost(128, seed)
    out.append(("fig9_claim_nvm_faster_than_ssd", ssd / nvm, "x speedup (>1)"))
    out.append(("fig9_claim_esr128_slower_than_nvm", esr128 / nvm, "x (>1)"))
    return out
