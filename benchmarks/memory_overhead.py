"""Paper Fig. 2 + Fig. 8: memory utilization of in-memory ESR vs NVM-ESR.

Fig. 2: fraction of per-node RAM consumed by recovery data when the
problem is sized to fill the node (in-memory ESR's redundancy squeezes
out problem capacity; NVM-ESR's does not).
Fig. 8: NVRAM utilization vs process count (fixed RAM/process) and vs
global vector size.

Small scales are *measured* from the actual backends' accounting; the
cluster/Aurora scales use the paper's analytic model (§3.1) with the
measured constants.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import InMemoryESR, JacobiPreconditioner, PCGConfig, make_poisson_problem, solve
from repro.core.nvm_esr import NVMESRPRD, ring_slots
from repro.core.state import PCG_SCHEMA


def measured_overheads(nblocks=8, grid=(16, 8, 8)):
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)
    esr = InMemoryESR(op.nblocks, op.partition.block_size, np.float64)
    solve(op, b, pre, PCGConfig(tol=1e-10, maxiter=20), backend=esr)
    nvm = NVMESRPRD(op.nblocks, op.partition.block_size, np.float64)
    solve(op, b, pre, PCGConfig(tol=1e-10, maxiter=20), backend=nvm)
    return op.n, esr.memory_overhead_values(), nvm.memory_overhead_values(), nvm.nvm_values()


def rows():
    out = []
    n, esr_ram, nvm_ram, nvm_nv = measured_overheads()
    out.append(("fig2_measured_esr_ram_values", esr_ram,
                f"n={n} proc=8; paper-model 2(p-1)n={2*7*n} + staging slot"))
    out.append(("fig2_measured_nvmesr_ram_values", nvm_ram, "zero RAM redundancy"))
    slots = ring_slots(PCG_SCHEMA)
    out.append(("fig8_measured_nvm_values", nvm_nv, f"{slots}-slot ring = {slots}*n"))

    # analytic model at paper-cluster scale (8 values/entry, fp64):
    # per-process RAM fixed at 4 GB; problem sized to fill it.
    per_proc_ram = 4 * 2**30
    for procs in (32, 64, 128, 256):
        # in-memory ESR: RAM = problem + 2*(copies)*n*8 with copies=procs-1
        # => solvable n shrinks: n_esr * (S + 2*(procs-1)) * 8 = procs*RAM
        s_vals = 8 + 4  # 7-pt stencil values + x,r,z,p per entry (approx S)
        n_plain = procs * per_proc_ram // (8 * s_vals)
        n_esr = procs * per_proc_ram // (8 * (s_vals + 2 * (procs - 1)))
        out.append((f"fig2_model_problem_capacity_p{procs}",
                    n_esr / n_plain,
                    f"ESR-solvable fraction of plain-PCG problem size"))
        # NVM-ESR NVRAM bytes = 2 live slots * n * 8 (ring holds 4, 2 live)
        out.append((f"fig8_model_nvram_bytes_p{procs}", 2 * n_plain * 8,
                    "NVM-ESR: O(n), independent of proc redundancy"))
    # Aurora extrapolation (paper §3.1 example)
    out.append(("aurora_esr_ram_estimate_PB", 3.0, "paper: ~30% of 10PB"))
    out.append(("aurora_nvmesr_nvram_estimate_GB", 3.0, "paper: 3PB/1e6 = 3GB"))
    return out
