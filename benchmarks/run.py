"""Benchmark harness: one module per paper figure/table.

Prints ``name,value,derived`` CSV rows.  Values are microseconds for
time-like rows (modeled with paper-cluster calibration constants where
the real hardware is simulated — see repro/nvm/store.py), bytes/ratios
otherwise (stated per row).

Usage: ``python benchmarks/run.py [module] [--smoke]``.  ``--smoke``
shrinks problem sizes (exported as ``REPRO_BENCH_SMOKE=1`` for modules
that honor it) — the CI dry-run path.

Modules:
  memory_overhead     — paper Fig. 2 + Fig. 8 (RAM/NVRAM utilization)
  persist_homogeneous — paper Fig. 9 (homogeneous persistence tiers)
  persist_prd         — paper Fig. 10 (PRD sub-cluster over RDMA)
  iteration_overhead  — wall-clock per-iteration overhead + recovery
  solver_roofline     — ESR vs NVM-ESR collective bytes on the mesh
  solver_zoo          — per-solver persist overhead across backends
  overlap_campaign    — sync vs overlapped persistence + failure campaigns
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    args = [a for a in sys.argv[1:]]
    while "--smoke" in args:
        args.remove("--smoke")
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    if len(args) > 1:
        raise SystemExit(f"at most one module may be selected, got {args}")
    only = args[0] if args else None

    import jax
    jax.config.update("jax_enable_x64", True)

    from benchmarks import (
        iteration_overhead,
        memory_overhead,
        overlap_campaign,
        persist_homogeneous,
        persist_prd,
        solver_roofline,
        solver_zoo,
    )

    modules = [
        ("memory_overhead", memory_overhead),
        ("persist_homogeneous", persist_homogeneous),
        ("persist_prd", persist_prd),
        ("iteration_overhead", iteration_overhead),
        ("solver_roofline", solver_roofline),
        ("solver_zoo", solver_zoo),
        ("overlap_campaign", overlap_campaign),
    ]
    if only is not None and only not in {name for name, _ in modules}:
        raise SystemExit(f"unknown module {only!r}; have "
                         f"{sorted(name for name, _ in modules)}")
    print("name,value,derived")
    failed = []
    for name, mod in modules:
        if only and name != only:
            continue
        t0 = time.perf_counter()
        try:
            for row_name, value, derived in mod.rows():
                print(f"{row_name},{value:.6g},{derived}")
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            traceback.print_exc()
        print(f"_bench_{name}_wall_s,{time.perf_counter()-t0:.2f},harness timing")
    if failed:
        for name, err in failed:
            print(f"_bench_{name}_FAILED,0,{err}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
