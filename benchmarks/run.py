"""Benchmark harness: one module per paper figure/table.

Prints ``name,value,derived`` CSV rows.  Values are microseconds for
time-like rows (modeled with paper-cluster calibration constants where
the real hardware is simulated — see repro/nvm/store.py), bytes/ratios
otherwise (stated per row).

Usage: ``python benchmarks/run.py [module] [--smoke] [--seed N]
[--json [--out PATH]]``.

- ``--smoke`` shrinks problem sizes (exported as ``REPRO_BENCH_SMOKE=1``
  for modules that honor it) — the CI dry-run path.
- ``--seed N`` threads an explicit seed through every module whose
  ``rows()`` accepts one (also exported as ``REPRO_BENCH_SEED``), so
  two identical invocations produce identical rows.
- ``--json`` emits the BENCH_solver.json perf trajectory
  (``bench_trajectory.build``) instead of CSV rows; ``--out PATH``
  overrides the default location (the repo root).  The document is
  deterministic for a fixed seed modulo its ``wall`` subtrees —
  ``tools/check_bench.py`` validates schema and determinism.

Modules:
  memory_overhead     — paper Fig. 2 + Fig. 8 (RAM/NVRAM utilization)
  persist_homogeneous — paper Fig. 9 (homogeneous persistence tiers)
  persist_prd         — paper Fig. 10 (PRD sub-cluster over RDMA)
  iteration_overhead  — wall-clock per-iteration overhead + recovery
  solver_roofline     — ESR vs NVM-ESR collective bytes on the mesh
  solver_zoo          — per-solver persist overhead across backends
  overlap_campaign    — sync vs overlapped persistence + failure campaigns
  bench_trajectory    — the BENCH_solver.json trajectory (headline CSV view)
"""
from __future__ import annotations

import inspect
import json
import os
import sys
import time
import traceback

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_solver.json")


def _parse_args(argv):
    args = list(argv)
    opts = {"smoke": False, "json": False, "seed": 0,
            "out": DEFAULT_BENCH_JSON}
    while "--smoke" in args:
        args.remove("--smoke")
        opts["smoke"] = True
    while "--json" in args:
        args.remove("--json")
        opts["json"] = True
    for flag, key, cast in (("--seed", "seed", int), ("--out", "out", str)):
        while flag in args:
            i = args.index(flag)
            try:
                opts[key] = cast(args[i + 1])
            except (IndexError, ValueError):
                raise SystemExit(f"{flag} needs a {cast.__name__} argument")
            del args[i:i + 2]
    if len(args) > 1:
        raise SystemExit(f"at most one module may be selected, got {args}")
    opts["only"] = args[0] if args else None
    return opts


def _call_rows(mod, seed: int):
    """Call ``mod.rows()``, threading the seed when the module takes
    one — the determinism contract of ``--seed``."""
    if "seed" in inspect.signature(mod.rows).parameters:
        return mod.rows(seed=seed)
    return mod.rows()


def main() -> None:
    opts = _parse_args(sys.argv[1:])
    if opts["smoke"]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    os.environ["REPRO_BENCH_SEED"] = str(opts["seed"])
    if opts["json"]:
        # The trajectory's sharded rows (DESIGN.md §10) need a device
        # mesh; fake 8 host devices BEFORE jax imports (flag is inert
        # after).  CSV module runs keep the real 1-device view.
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()

    import jax
    jax.config.update("jax_enable_x64", True)

    if opts["json"]:
        # The JSON trajectory path: one deterministic document, written
        # where future PRs can diff it (tools/check_bench.py gates it).
        from benchmarks import bench_trajectory

        doc = bench_trajectory.build(seed=opts["seed"])
        with open(opts["out"], "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True, allow_nan=False)
            f.write("\n")
        print(f"wrote {opts['out']} ({len(doc['specs'])} specs, "
              f"seed={opts['seed']}, smoke={doc['smoke']})")
        return

    from benchmarks import (
        bench_trajectory,
        iteration_overhead,
        memory_overhead,
        overlap_campaign,
        persist_homogeneous,
        persist_prd,
        solver_roofline,
        solver_zoo,
    )

    modules = [
        ("memory_overhead", memory_overhead),
        ("persist_homogeneous", persist_homogeneous),
        ("persist_prd", persist_prd),
        ("iteration_overhead", iteration_overhead),
        ("solver_roofline", solver_roofline),
        ("solver_zoo", solver_zoo),
        ("overlap_campaign", overlap_campaign),
        ("bench_trajectory", bench_trajectory),
    ]
    only = opts["only"]
    if only is not None and only not in {name for name, _ in modules}:
        raise SystemExit(f"unknown module {only!r}; have "
                         f"{sorted(name for name, _ in modules)}")
    print("name,value,derived")
    failed = []
    for name, mod in modules:
        if only and name != only:
            continue
        t0 = time.perf_counter()
        try:
            for row_name, value, derived in _call_rows(mod, opts["seed"]):
                print(f"{row_name},{value:.6g},{derived}")
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            traceback.print_exc()
        print(f"_bench_{name}_wall_s,{time.perf_counter()-t0:.2f},harness timing")
    if failed:
        for name, err in failed:
            print(f"_bench_{name}_FAILED,0,{err}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
