"""Solver zoo sweep: per-solver persistence overhead across backends.

Extends the paper's PCG-only Figs. 9/10 view to every registered solver:
for each (solver, backend) cell the modeled persist cost per persistence
event, the slot payload size implied by the solver's recovery schema, and
a recovery run demonstrating mid-solve multi-block failure tolerance.

Smoke mode (``REPRO_BENCH_SMOKE=1`` or ``run.py --smoke``) shrinks the
grid and loosens the tolerance so the sweep doubles as a CI dry run.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.solvers import (
    BACKENDS,
    SOLVERS,
    FailurePlan,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
)


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def rows():
    out = []
    if _smoke():
        grid, nblocks, tol, fail_at = (8, 8, 8), 4, 1e-8, 3
    else:
        grid, nblocks, tol, fail_at = (32, 16, 16), 8, 1e-10, 10
    op, b = make_poisson_problem(*grid, nblocks=nblocks)
    pre = JacobiPreconditioner(op)
    bs = op.partition.block_size

    for sname in sorted(SOLVERS):
        opts = {"m": 4} if sname == "gmres" else {}
        solver = make_solver(sname, op, pre, **opts)
        schema = solver.schema
        out.append((f"zoo_{sname}_slot_bytes",
                    schema.slot_nbytes(bs, np.float64),
                    f"{len(schema.vectors)}v+{len(schema.scalars)}s "
                    f"history={schema.history}"))

        # unprotected baseline
        _, rep0, _ = solve(solver, op, b, pre,
                           SolveConfig(tol=tol, maxiter=20000))
        out.append((f"zoo_{sname}_iterations", rep0.iterations,
                    f"to {tol:g}, converged={rep0.converged}"))

        for bname in sorted(BACKENDS):
            solver = make_solver(sname, op, pre, **opts)
            be = make_backend(bname, op, solver=solver)
            _, rep, _ = solve(solver, op, b, pre,
                              SolveConfig(tol=tol, maxiter=20000), backend=be)
            per_event = rep.persist_cost_s / max(rep.persist_events, 1)
            out.append((f"zoo_{sname}_{bname}_persist_us_per_event",
                        per_event * 1e6,
                        f"{rep.persist_events} events, modeled"))

        # recovery demonstration on the PRD architecture
        solver = make_solver(sname, op, pre, **opts)
        be = make_backend("nvm-prd", op, solver=solver)
        f_at = min(fail_at, 3) if sname == "gmres" else fail_at
        _, rep, _ = solve(solver, op, b, pre,
                          SolveConfig(tol=tol, maxiter=20000), backend=be,
                          failures=[FailurePlan(f_at, (1, 2))])
        out.append((f"zoo_{sname}_recovered_iterations", rep.iterations,
                    f"recovered={rep.failures_recovered} "
                    f"wasted={rep.wasted_iterations} converged={rep.converged}"))
    return out
