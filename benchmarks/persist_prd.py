"""Paper Fig. 10: time overhead of one persistence iteration in the PRD
sub-cluster architecture:

  - NVM-ESR: MPI OSC over RDMA to remote NVRAM (PSCW, wait_persist)
  - MPI OSC over RDMA to remote RAM (no persist) — the persistence cost
  - remote SATA-SSD via SSH-FS — the traditional C/R reference
  - in-memory ESR (for the crossover with small process counts)

The PRD NIC serializes incoming puts, so origin-visible time grows with
total bytes — the Fig. 10 trend.  PSCW lets origins exit before the PRD
flush: ``origin`` vs ``target`` columns show the overlap win.
"""
from __future__ import annotations

import numpy as np

from repro.core.esr import InMemoryESR
from repro.core.nvm_esr import NVMESRPRD
from repro.nvm.store import Tier

LOCAL_N = 176_400


def prd_costs(nprocs: int, tier: Tier, network: str, seed: int = 0):
    be = NVMESRPRD(nprocs, LOCAL_N, np.float64, tier=tier, network=network,
                   async_drain=True)
    rng = np.random.default_rng(seed)
    p = rng.standard_normal(nprocs * LOCAL_N)
    origin = be.persist_set(1, {"beta": 0.5}, {"p": p})
    target = be.drain()
    return origin, target


def rows(seed: int = 0):
    out = []
    for nprocs in (1, 8, 32, 64, 128, 256):
        o_nvm, t_nvm = prd_costs(nprocs, Tier.NVM, "rdma", seed)
        o_ram, _ = prd_costs(nprocs, Tier.DRAM, "rdma", seed)
        o_ssd, t_ssd = prd_costs(nprocs, Tier.SSD, "sshfs", seed)
        esr = InMemoryESR(max(nprocs, 2), LOCAL_N, np.float64)
        e = esr.persist_set(1, {"beta": 0.5},
                            {"p": np.zeros(max(nprocs, 2) * LOCAL_N)}) / max(nprocs, 2)
        out.append((f"fig10_prd_rdma_nvm_p{nprocs}", o_nvm * 1e6,
                    f"origin us; target drain {t_nvm*1e6:.0f}us overlapped"))
        out.append((f"fig10_prd_rdma_ram_p{nprocs}", o_ram * 1e6,
                    "origin us (no persistence)"))
        out.append((f"fig10_prd_sshfs_ssd_p{nprocs}", o_ssd * 1e6, "origin us"))
        out.append((f"fig10_esr_inmemory_p{nprocs}", e * 1e6, "per-proc us"))
    # headline claims
    o_nvm, _ = prd_costs(128, Tier.NVM, "rdma", seed)
    o_ssd, _ = prd_costs(128, Tier.SSD, "sshfs", seed)
    o_ram, _ = prd_costs(128, Tier.DRAM, "rdma", seed)
    out.append(("fig10_claim_nvm_vs_remote_ssd_128p", o_ssd / o_nvm, "x faster (>1)"))
    out.append(("fig10_claim_persist_overhead_vs_ram", o_nvm / o_ram,
                "x (persistence cost is small, ~1)"))
    return out
