#!/usr/bin/env bash
# Tier-1 test entry point (used by CI and locally).
#
# - JAX_ENABLE_X64: exact-state-reconstruction claims are float64 claims.
# - xla_force_host_platform_device_count=8: exercises the multi-device
#   code paths on CPU hosts.  Tests that must see exactly 1 device
#   (dry-run/elastic-restore) re-exec in subprocesses that override
#   XLA_FLAGS themselves, so the suite is flag-order independent.
set -euo pipefail
cd "$(dirname "$0")"

export JAX_ENABLE_X64=1
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Static invariants first (stdlib-only, fast): src/ must lint clean —
# any unsuppressed repro-lint finding fails the run before pytest starts.
python -m tools.repro_lint src

exec python -m pytest -x -q "$@"
