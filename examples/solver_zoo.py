"""The recoverable solver zoo, end to end.

Runs every registered solver (PCG, weighted Jacobi, Chebyshev, BiCGStab,
restarted GMRES) on the same 3-D Poisson problem, injects the same
3-block simultaneous failure mid-solve, and recovers through NVM-ESR/PRD
— each solver persisting its own schema-declared minimal recovery set
through the same backend machinery.

    PYTHONPATH=src python examples/solver_zoo.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import JacobiPreconditioner, make_poisson_problem
from repro.solvers import (
    SOLVERS,
    FailurePlan,
    SolveConfig,
    make_backend,
    make_solver,
    solve,
)


def main() -> None:
    from repro.launch.report import (capability_matrix_table,
                                     solve_report_table, storage_values)
    from repro.nvm.backend import backend_names

    op, b = make_poisson_problem(32, 16, 16, nblocks=8)
    pre = JacobiPreconditioner(op)
    bs = op.partition.block_size
    bnorm = float(jnp.linalg.norm(b))
    reports = []

    print("Registered backends and their declared capabilities "
          "(DESIGN.md §7/§8); storage overhead is relative to one "
          "unreplicated PRD node:")
    print(capability_matrix_table(
        ((name, make_backend(name, op)) for name in backend_names()),
        baseline_values=storage_values(make_backend("nvm-prd", op))))
    print()

    print(f"{'solver':10s} {'set':22s} {'iters':>5s} {'relres':>9s} "
          f"{'persist(ms)':>11s} {'NVM KiB':>8s} {'wall(s)':>8s}")
    for name in sorted(SOLVERS):
        opts = {"m": 8} if name == "gmres" else {}
        solver = make_solver(name, op, pre, **opts)
        backend = make_backend("nvm-prd", op, solver=solver)
        fail_at = 4 if name == "gmres" else 30
        schema = solver.schema
        set_desc = "{" + ",".join(schema.vectors + schema.scalars) + "}" \
            + f" h={schema.history}"
        t0 = time.perf_counter()
        state, rep, _ = solve(
            solver, op, b, pre,
            SolveConfig(tol=1e-10, maxiter=20000, persist_mode="overlap"),
            backend=backend, failures=[FailurePlan(fail_at, (1, 2, 6))])
        wall = time.perf_counter() - t0
        res = float(jnp.linalg.norm(b - op.apply(state.x))) / bnorm
        nvm_kib = backend.nvm_values() * 8 / 1024
        print(f"{name:10s} {set_desc:22s} {rep.iterations:5d} {res:9.1e} "
              f"{rep.persist_cost_s*1e3:11.2f} {nvm_kib:8.0f} {wall:8.2f}")
        assert rep.failures_recovered == 1 and rep.converged, name
        reports.append(rep)

    print("\nFull solver reports (overlapped persistence pipeline):")
    print(solve_report_table(reports))


if __name__ == "__main__":
    main()
