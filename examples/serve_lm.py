"""Batched serving demo: prefill + token-by-token decode over sharded KV
caches (ring buffers on sliding-window layers), greedy sampling.

    PYTHONPATH=src python examples/serve_lm.py [--arch starcoder2_3b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import registry as R
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b",
                    help="any assigned arch id (SMOKE config is used on CPU)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = R.get_config(args.arch, smoke=True)
    if cfg.frontend == "vision":
        raise SystemExit("vision arch serving needs patch-embedding inputs; "
                         "use a text arch for this demo")
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))

    inputs_wrap = (lambda p, t, c: R.make_prefill(cfg)(
        p, {"tokens": t, "frames": jnp.zeros((t.shape[0], cfg.enc_seq,
                                              cfg.d_model), cfg.cdt)}, c)
    ) if cfg.family == "encdec" else (
        lambda p, t, c: R.make_prefill(cfg)(p, {"tokens": t}, c))

    eng = ServeEngine(
        prefill_fn=inputs_wrap,
        decode_fn=R.make_decode(cfg),
        cache_init=lambda b, s: R.init_caches(cfg, b, s)[0],
    )

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = eng.generate(params, prompt, steps=args.gen)
    wall = time.perf_counter() - t0
    print(f"arch {cfg.name}: generated {out.shape} tokens in {wall:.2f}s "
          f"({args.batch*args.gen/wall:.1f} tok/s incl. compile)")
    print("sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
