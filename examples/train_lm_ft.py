"""End-to-end fault-tolerant LM training (~100M params, a few hundred steps).

The training loop runs under the paper's persistence machinery
(DESIGN.md §4): minimal-state NVM checkpoints (double-buffered slots,
async PSCW-style drain), a Young/Daly-tuned persistence period, and a
mid-run host failure that is healed by elastic restore.

    PYTHONPATH=src python examples/train_lm_ft.py [--steps 300] [--small]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.checkpoint import CheckpointConfig, NVMCheckpointManager
from repro.ft.period import PersistencePeriodTuner
from repro.ft.recovery import TrainingRecovery, inject_host_failure
from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.training.data import SyntheticCorpus
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


def model_100m() -> ModelConfig:
    # ~106M params: llama-family, 12L x 768
    return ModelConfig(name="lm-100m", family="lm", n_layers=12, d_model=768,
                       n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000,
                       mlp_act="silu_gated", attn_chunk=128)


def model_small() -> ModelConfig:  # CI-speed variant
    return ModelConfig(name="lm-5m", family="lm", n_layers=4, d_model=128,
                       n_heads=4, n_kv_heads=2, d_ff=512, vocab=2048,
                       attn_chunk=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/nvm_esr_lm_ckpt")
    ap.add_argument("--fail-at", type=int, default=150)
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params: {n_params/1e6:.1f}M")

    step_fn = jax.jit(make_train_step(R.make_train_forward(cfg),
                                      AdamWConfig(lr=3e-4, warmup_steps=50)))
    data = SyntheticCorpus(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=1)
    opt = adamw_init(params)

    mgr = NVMCheckpointManager(CheckpointConfig(args.ckpt_dir, async_drain=True))
    tuner = PersistencePeriodTuner(mtbf_s=300.0, min_period=10, max_period=100)
    rec = TrainingRecovery(mgr, tuner)

    state = {"params": params, "opt": opt}
    s = 0
    injected = False
    t_start = time.perf_counter()
    while s < args.steps:
        if s == args.fail_at and not injected:
            injected = True
            print(f"\n!!! host failure injected at step {s} — volatile state lost")
            state = inject_host_failure(state)
            state, s, _ = rec.recover(state, failed_step=s)
            print(f"    recovered from NVM checkpoint at step {s} "
                  f"(wasted {rec.steps_wasted} steps — ESRP discard cost)\n")
            continue
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        p, o, m = step_fn(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        s += 1
        rec.observe_step(time.perf_counter() - t0)
        if s % tuner.period == 0:
            mgr.save_async(state, step=s)
        if s % 25 == 0 or s == 1:
            print(f"step {s:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"persist-period {tuner.period} "
                  f"(overhead {tuner.expected_overhead_fraction()*100:.2f}%)")
    mgr.join()
    wall = time.perf_counter() - t_start
    print(f"\ndone: {args.steps} steps in {wall:.1f}s "
          f"({wall/args.steps*1e3:.0f} ms/step), "
          f"failures recovered: {rec.failures_recovered}")


if __name__ == "__main__":
    main()
