"""End-to-end comparison of every recovery mechanism on one problem:

  - plain PCG (no fault tolerance)
  - in-memory ESR (peer-RAM redundancy, the paper's baseline)
  - NVM-ESR homogeneous (local simulated NVRAM via the PMDK-like pool)
  - NVM-ESR/PRD (remote NVRAM over MPI-OSC/RDMA + PSCW)
  - ESRP periodic persistence (period 5) on NVM-ESR/PRD

Each fault-tolerant run is hit with the same 3-block simultaneous
failure; the table shows overheads and that every variant converges to
the same solution.

    PYTHONPATH=src python examples/solve_poisson_recovery.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (
    FailurePlan,
    InMemoryESR,
    JacobiPreconditioner,
    NVMESRHomogeneous,
    NVMESRPRD,
    PCGConfig,
    make_poisson_problem,
    solve,
)


def main() -> None:
    op, b = make_poisson_problem(32, 16, 16, nblocks=8)
    pre = JacobiPreconditioner(op)
    fail = [FailurePlan(at_iteration=30, blocks=(1, 2, 6))]
    bs = op.partition.block_size

    runs = {
        "plain (no FT)": (None, [], PCGConfig(tol=1e-10)),
        "in-memory ESR": (InMemoryESR(op.nblocks, bs, np.float64), fail,
                          PCGConfig(tol=1e-10)),
        "NVM-ESR homog": (NVMESRHomogeneous(op.nblocks, bs, np.float64), fail,
                          PCGConfig(tol=1e-10)),
        "NVM-ESR/PRD": (NVMESRPRD(op.nblocks, bs, np.float64), fail,
                        PCGConfig(tol=1e-10)),
        "ESRP T=5 /PRD": (NVMESRPRD(op.nblocks, bs, np.float64), fail,
                          PCGConfig(tol=1e-10, persistence_period=5)),
    }

    print(f"{'variant':15s} {'iters':>5s} {'wasted':>6s} {'relres':>9s} "
          f"{'persist(ms)':>11s} {'RAM vals':>10s} {'NVM vals':>9s} {'wall(s)':>8s}")
    xs = {}
    for name, (be, fl, cfgc) in runs.items():
        t0 = time.perf_counter()
        st, rep, _ = solve(op, b, pre, cfgc, backend=be, failures=fl)
        wall = time.perf_counter() - t0
        xs[name] = np.asarray(st.x)
        ram = be.memory_overhead_values() if be else 0
        nvm = be.nvm_values() if be else 0
        print(f"{name:15s} {rep.iterations:5d} {rep.wasted_iterations:6d} "
              f"{rep.final_relres:9.1e} {rep.persist_cost_s*1e3:11.2f} "
              f"{ram:10d} {nvm:9d} {wall:8.2f}")

    ref = xs["plain (no FT)"]
    for name, x in xs.items():
        d = float(np.max(np.abs(x - ref)))
        print(f"  |x - x_plain|_inf [{name}] = {d:.2e}")
        assert d < 1e-8, name


if __name__ == "__main__":
    main()
