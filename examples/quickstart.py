"""Quickstart: the paper in 60 seconds.

Solve a 3-D Poisson system with distributed PCG, kill two "nodes"
mid-solve, and watch NVM-ESR reconstruct the exact state from the
persisted minimal set (two p-vectors and a scalar) — no checkpoint of
x/r/z ever taken.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (
    FailurePlan,
    JacobiPreconditioner,
    NVMESRPRD,
    PCGConfig,
    make_poisson_problem,
    solve,
)


def main() -> None:
    # 24x16x16 grid = 6144 unknowns over 8 process blocks (z-slabs)
    op, b = make_poisson_problem(24, 16, 16, nblocks=8)
    pre = JacobiPreconditioner(op)

    # recovery data goes to a (simulated) remote NVRAM PRD node via
    # MPI-OSC/PSCW — O(n) NVM bytes, ZERO peer RAM
    backend = NVMESRPRD(op.nblocks, op.partition.block_size, np.float64)

    state, report, _ = solve(
        op, b, pre, PCGConfig(tol=1e-10),
        backend=backend,
        failures=[FailurePlan(at_iteration=25, blocks=(2, 5))],
    )

    res = float(jnp.linalg.norm(b - op.apply(state.x)) / jnp.linalg.norm(b))
    print(f"converged       : {report.converged} in {report.iterations} iterations")
    print(f"final rel. res. : {res:.2e}")
    print(f"failures healed : {report.failures_recovered} "
          f"(blocks 2 and 5 died at iteration 25)")
    print(f"wasted iters    : {report.wasted_iterations} (ESR persists every iter)")
    print(f"RAM redundancy  : {backend.memory_overhead_values()} values "
          f"(in-memory ESR would hold {2*(op.nblocks-1)*op.n})")
    print(f"NVM footprint   : {backend.nvm_values()} values (4-slot ring of p-shards)")
    assert report.converged and res < 1e-9


if __name__ == "__main__":
    main()
