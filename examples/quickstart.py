"""Quickstart: the paper in 60 seconds, through the ``repro.api`` façade.

Solve a 3-D Poisson system with distributed PCG over two *mirrored*
(simulated) NVRAM PRD nodes, then kill two compute "nodes" AND one of
the PRD nodes mid-solve — and watch recovery reconstruct the exact
state from the surviving mirror's minimal persisted set (two p-vectors
and a scalar).  No checkpoint of x/r/z is ever taken, and the
persistence commits hide behind the solver's own compute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro import api


def main() -> None:
    # 24x16x16 grid = 6144 unknowns over 8 process blocks (z-slabs)
    problem = api.Problem.poisson(24, 16, 16, nblocks=8)

    result = api.solve(
        problem,
        api.SolverSpec("pcg", tol=1e-10),
        # RAID-1 over two PRD nodes: the single-point-of-failure the
        # paper scopes out, closed by composition (DESIGN.md §7)
        api.ResilienceSpec("replicated(nvm-prd x2)", persist_mode="overlap"),
        failures=[api.FailureEvent(blocks=(2, 5), at_iteration=25, prd=True)],
    )

    rep = result.report
    caps = result.capabilities
    print(f"backend caps    : durability={caps.durability} "
          f"survives_prd_loss={caps.survives_prd_loss} "
          f"overlap={caps.overlap}")
    print(f"converged       : {result.converged} in {result.iterations} iterations")
    print(f"final rel. res. : {result.relres:.2e}")
    print(f"failures healed : {rep.failures_recovered} "
          f"(blocks 2 and 5 + one PRD node died at iteration 25)")
    print(f"PRD nodes lost  : {rep.storage_failures} (absorbed by the mirror)")
    print(f"wasted iters    : {rep.wasted_iterations}")
    print(f"persist hidden  : {rep.persist_hidden_fraction:.0%} of the "
          f"mirrored commit cost rode behind compute")
    print(f"RAM redundancy  : {result.backend.memory_overhead_values()} values "
          f"(in-memory ESR would hold "
          f"{2 * (problem.op.nblocks - 1) * problem.op.n})")
    print(f"NVM footprint   : {result.backend.nvm_values()} values "
          f"(a 4-slot ring of p-shards, x2 mirrors)")
    assert result.converged and result.relres < 1e-9
    assert rep.failures_recovered == 1 and rep.storage_failures == 1

    # And instead of picking the spec by hand, ask the advisor: for a
    # campaign that loses TWO storage nodes, the cheapest survivor is
    # the Reed-Solomon stripe (1.33x storage), not the 3x triple mirror.
    from repro.launch.report import spec_advice_table

    double_loss = [
        api.FailureEvent(blocks=(2,), at_iteration=20, prd=True),
        api.FailureEvent(blocks=(5,), at_iteration=30, prd=True),
    ]
    advice = api.advise(problem, double_loss)
    print()
    print("advisor verdict for a double-storage-loss campaign:")
    print(spec_advice_table(advice))
    assert advice.chosen == "erasure(nvm-prd x6+2p)"


if __name__ == "__main__":
    main()
