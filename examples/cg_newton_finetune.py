"""The paper's technique INSIDE an NN training loop: a Gauss-Newton /
CG fine-tuning step whose inner linear solver is the fault-tolerant PCG.

Second-order fine-tuning of a tiny regression head solves
``(J'J + lambda I) dx = J'r`` every outer step — a symmetric positive
definite system, i.e. exactly the solver class ESR covers.  We run the
inner CG under NVM-ESR and kill a block mid-solve on one of the outer
iterations; training is unaffected because the solver state is
reconstructed exactly.

    PYTHONPATH=src python examples/cg_newton_finetune.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (
    DenseOperator,
    FailurePlan,
    JacobiPreconditioner,
    NVMESRPRD,
    PCGConfig,
    solve,
)


def main() -> None:
    rng = np.random.default_rng(0)
    n_feat, n_out, n_data = 64, 8, 512
    w_true = rng.standard_normal((n_feat, n_out))
    x_data = rng.standard_normal((n_data, n_feat))
    y_data = x_data @ w_true + 0.01 * rng.standard_normal((n_data, n_out))

    w = jnp.zeros((n_feat, n_out))
    lam = 1e-3

    def residual(w):
        return x_data @ w - y_data

    # Gauss-Newton normal operator (J'J + lam I) is SPD and fixed here
    a = np.asarray(x_data.T @ x_data + lam * np.eye(n_feat))
    op = DenseOperator(a, nblocks=8)
    pre = JacobiPreconditioner(op)

    for outer in range(5):
        r = residual(w)
        loss = float(jnp.mean(r * r))
        g = jnp.asarray(x_data.T @ r)           # (n_feat, n_out)
        # one fault-tolerant CG solve per output column
        dw = []
        for j in range(n_out):
            backend = NVMESRPRD(op.nblocks, op.partition.block_size, np.float64)
            failures = [FailurePlan(5, (2, 3))] if (outer == 2 and j == 0) else []
            st, rep, _ = solve(op, g[:, j], pre,
                               PCGConfig(tol=1e-10, local_solve="dense"),
                               backend=backend, failures=failures)
            if failures:
                print(f"  [outer {outer}] inner-CG failure healed: "
                      f"recovered={rep.failures_recovered}, "
                      f"iters={rep.iterations}")
            dw.append(st.x)
        w = w - jnp.stack(dw, axis=1)
        print(f"outer {outer}: loss {loss:.6f}")

    final = float(jnp.mean(residual(w) ** 2))
    print(f"final loss {final:.2e} (noise floor ~1e-4)")
    assert final < 1e-3


if __name__ == "__main__":
    main()
